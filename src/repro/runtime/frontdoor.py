"""Fleet front door — multi-tenant admission for the serving stack.

Everything through PR 9 is a closed-loop single-operator engine: one
``StreamEngine.feed()`` call owns the whole box.  The paper's
applications (field biometrics, surveillance, disaster response) are
explicitly multi-consumer — several operators and bulk jobs sharing one
CHAMP box — so ingest needs an *admission* layer in front of dispatch:

``Tenant``
    One traffic source: a priority class (0 = highest; classes shed
    last-to-first), a WFQ ``weight`` (long-run service share under
    contention), an optional token-bucket ``rate_fps`` credit, an
    optional end-to-end ``slo_s`` target (drives the engine's hedge
    deadlines), and a bounded per-tenant queue.

``FrontDoor``
    The admission controller.  Arrivals ``offer()``; the door either
    admits immediately (capacity slot open + token available), parks the
    frame in the tenant's queue, or sheds it.  Queued frames drain by
    weighted-fair queuing — stride scheduling on virtual finish times,
    so long-run admission shares converge to the weight ratio under any
    arrival interleaving.  Under aggregate overload the *lowest* class
    with backlog is preempted first (graceful degradation: bulk work
    sheds, interactive work keeps its share — never queue collapse).

    Backpressure closes the loop from fleet health: the admission pacer
    runs off the engine's *live* capacity (parked hubs and dead lanes
    contribute nothing; throttled hubs contribute ``1/inflation``;
    quarantine probation discounts a lane's rate), and every tenant's
    token refill is scaled by ``credit = live/nominal`` — a parked hub
    shrinks the whole credit pool instead of letting queues balloon.

The one-flag discipline (the ``_chaos`` / ``trace=None`` lesson): a
door with a single tenant and no rate caps is **not engaged** —
``offer()`` is a pure synchronous pass-through, so ``feed()`` on a
default door is float-for-float bit-identical to the pre-door ingest
path.  All pacing/queueing/shedding machinery exists only behind
``engaged``.

Conservation invariant (property-tested): per tenant, at any instant,
``offered == admitted + shed + queued``.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

from repro.runtime import trace as trc
from repro.runtime.metrics import StreamingHistogram

# canonical priority-class names (any int >= 0 is legal; these are the
# conventional tiers used by serve.py and the bench)
CLASS_NAMES = {0: "interactive", 1: "standard", 2: "bulk"}


def class_name(priority: int) -> str:
    return CLASS_NAMES.get(priority, f"class{priority}")


@dataclass(frozen=True)
class Tenant:
    """One admission contract.  Frozen: identity and terms never mutate
    mid-run (re-negotiation = new tenant)."""
    name: str
    priority: int = 1             # class: 0 sheds last, larger sheds first
    weight: float = 1.0           # WFQ share under contention
    rate_fps: Optional[float] = None   # token refill rate; None = uncapped
    burst: float = 16.0           # token-bucket depth (frames)
    slo_s: Optional[float] = None      # end-to-end latency target
    queue_cap: int = 256          # per-tenant front-door queue bound

    def __post_init__(self):
        if self.priority < 0:
            raise ValueError("priority class must be >= 0")
        if self.weight <= 0.0:
            raise ValueError("weight must be positive")
        if self.rate_fps is not None and self.rate_fps <= 0.0:
            raise ValueError("rate_fps must be positive (or None)")
        if self.burst < 1.0:
            raise ValueError("burst must allow at least one frame")
        if self.queue_cap < 1:
            raise ValueError("queue_cap must be >= 1")


class _TenantState:
    """Mutable per-tenant runtime: bucket, queue, WFQ clock, counters."""

    __slots__ = ("tenant", "tokens", "tok_t", "queue", "vt",
                 "offered", "admitted", "shed_overflow", "shed_preempted",
                 "queued_peak", "completed", "slo_miss", "wait_s", "lat")

    def __init__(self, tenant: Tenant):
        self.tenant = tenant
        self.tokens = tenant.burst      # start full: bursts admit cold
        self.tok_t = 0.0
        self.queue: deque = deque()
        self.vt = 0.0                   # WFQ virtual finish time
        self.offered = 0
        self.admitted = 0
        self.shed_overflow = 0          # dropped at the door (queue full)
        self.shed_preempted = 0         # evicted from queue by class shed
        self.queued_peak = 0
        self.completed = 0
        self.slo_miss = 0
        self.wait_s = 0.0               # total front-door queue wait
        self.lat = StreamingHistogram()

    @property
    def shed(self) -> int:
        return self.shed_overflow + self.shed_preempted

    def capped(self) -> bool:
        return self.tenant.rate_fps is not None


class FrontDoor:
    """Multi-tenant admission controller in front of ``StreamEngine``.

    ``headroom``        fraction of live capacity the pacer admits at
                        (< 1 keeps dispatch queues shallow so class-0
                        latency stays near service time under overload).
    ``min_credit``      floor on the health credit ``live/nominal`` so a
                        brief brown-out cannot zero every token bucket.
    ``max_poll_s``      drain re-check bound while backlogged: caps how
                        stale the capacity estimate can get after a hub
                        parks or recovers.
    ``total_queue_cap`` aggregate bound across all tenant queues; beyond
                        it the lowest backlogged class is preempted.
    ``inflight_s``      target pipeline sojourn: admissions stall once
                        ``live_fps * inflight_s`` frames are in flight,
                        so completions — not the capacity estimate —
                        clock admission under saturation and any
                        transient over-admission drains immediately.
    """

    def __init__(self, *, headroom: float = 0.95, min_credit: float = 0.05,
                 max_poll_s: float = 0.25, total_queue_cap: int = 1024,
                 inflight_s: float = 0.25, min_window: int = 4):
        if not 0.0 < headroom <= 1.0:
            raise ValueError("headroom must be in (0, 1]")
        self.headroom = headroom
        self.min_credit = min_credit
        self.max_poll_s = max_poll_s
        self.total_queue_cap = total_queue_cap
        self.inflight_s = inflight_s
        self.min_window = min_window
        self._inflight = 0              # admitted minus completed/lost
        self._states: Dict[str, _TenantState] = {}
        self.default_tenant: Optional[str] = None
        self.has_slo = False            # engine gates hedge coupling on this
        self._gate = 0.0                # next admission-slot time
        self._v = 0.0                   # WFQ virtual clock
        self._queued_total = 0
        self._drain_pending = False
        self.last_credit = 1.0
        # host hooks (bind): virtual clock, event scheduler, admission
        # sink, live/nominal capacity probe, optional flight recorder
        self._clock: Callable[[], float] = lambda: 0.0
        self._schedule: Callable[[float, Callable], object] = \
            lambda t, fn: (_ for _ in ()).throw(
                RuntimeError("FrontDoor not bound to a scheduler"))
        self._admit_cb: Callable[[object], None] = lambda m: None
        self._capacity: Callable[[], tuple] = lambda: (float("inf"),
                                                       float("inf"))
        self._tracer = None
        # capacity snapshots are cached per virtual timestamp: every
        # offer in one event cohort shares the lane scan
        self._cap_t = -1.0
        self._cap = (float("inf"), float("inf"))

    # -- configuration --------------------------------------------------------
    def add_tenant(self, tenant, **kw) -> Tenant:
        """Register a tenant (a ``Tenant`` or a name plus field kwargs).
        The first tenant registered is the default ``feed()`` target."""
        if not isinstance(tenant, Tenant):
            tenant = Tenant(name=str(tenant), **kw)
        elif kw:
            raise ValueError("pass a Tenant or kwargs, not both")
        if tenant.name in self._states:
            raise ValueError(f"tenant {tenant.name!r} already registered")
        self._states[tenant.name] = _TenantState(tenant)
        if self.default_tenant is None:
            self.default_tenant = tenant.name
        if tenant.slo_s is not None:
            self.has_slo = True
        return tenant

    def tenant(self, name: str) -> Tenant:
        return self._states[name].tenant

    @property
    def tenant_names(self):
        return list(self._states)

    @property
    def engaged(self) -> bool:
        """Admission machinery on?  A single uncapped tenant is a pure
        pass-through (the bit-identity contract); more than one tenant,
        or any rate credit, engages pacing/queueing/shedding."""
        if len(self._states) > 1:
            return True
        return any(st.capped() for st in self._states.values())

    # -- host binding ---------------------------------------------------------
    def bind(self, *, clock, schedule, admit, capacity, tracer=None):
        """Attach to a host engine: ``clock()`` -> now, ``schedule(t, fn)``
        defers a drain, ``admit(m)`` hands a frame to dispatch,
        ``capacity()`` -> ``(live_fps, nominal_fps)`` of the bottleneck
        stage.  ``StreamEngine.attach_frontdoor`` wires all four."""
        self._clock = clock
        self._schedule = schedule
        self._admit_cb = admit
        self._capacity = capacity
        self._tracer = tracer
        if not self._states:
            self.add_tenant("default")

    # -- admission ------------------------------------------------------------
    def offer(self, name: str, m, t: float) -> str:
        """One frame arrives for ``name`` at virtual time ``t``.  Returns
        the verdict: ``"admit"``, ``"queue"``, or ``"shed"``."""
        st = self._states[name]
        st.offered += 1
        if not self.engaged:           # pass-through: bit-identical ingest
            st.admitted += 1
            self._admit_cb(m)
            return "admit"
        live, credit = self._capacity_now(t)
        self._refill(st, t, credit)
        if not st.queue and t >= self._gate and live > 1e-6 \
                and self._inflight < self._window(live) \
                and (not st.capped() or st.tokens >= 1.0):
            self._admit_one(st, m, t, live)
            return "admit"
        return self._park_or_shed(st, m, t)

    def _park_or_shed(self, st: _TenantState, m, t: float) -> str:
        if len(st.queue) >= st.tenant.queue_cap:
            self._shed(st, m, t, "overflow")
            return "shed"
        if self._queued_total >= self.total_queue_cap:
            victim = self._shed_victim(st)
            if victim is None:          # arriving class is the lowest
                self._shed(st, m, t, "overflow")
                return "shed"
            evicted = victim.queue.pop()    # newest bulk frame goes first
            self._queued_total -= 1
            victim.shed_preempted += 1
            self._trace_shed(victim, evicted, t, "preempted")
        st.queue.append(m)
        self._queued_total += 1
        if len(st.queue) > st.queued_peak:
            st.queued_peak = len(st.queue)
        self._schedule_drain(t)
        return "queue"

    def _shed_victim(self, incoming: _TenantState) -> Optional[_TenantState]:
        """Lowest-class backlogged tenant strictly below the arrival's
        class (ties never preempt: a class cannot shed itself)."""
        victim = None
        for st in self._states.values():
            if not st.queue or st.tenant.priority <= incoming.tenant.priority:
                continue
            if victim is None or \
                    (st.tenant.priority, st.tenant.name) > \
                    (victim.tenant.priority, victim.tenant.name):
                victim = st
        return victim

    def _shed(self, st: _TenantState, m, t: float, why: str):
        st.shed_overflow += 1
        self._trace_shed(st, m, t, why)

    # -- pacing ---------------------------------------------------------------
    def _window(self, live: float) -> float:
        """Admission window: frames allowed in flight at once."""
        if live == float("inf"):
            return float("inf")
        return max(self.min_window, int(live * self.inflight_s))

    def _capacity_now(self, t: float):
        """(live_fps, credit) — cached per virtual timestamp."""
        if t != self._cap_t:
            self._cap_t = t
            self._cap = self._capacity()
        live, nominal = self._cap
        if nominal <= 0.0 or nominal == float("inf"):
            credit = 1.0
        else:
            credit = min(1.0, max(self.min_credit, live / nominal))
        self.last_credit = credit
        return live, credit

    def _refill(self, st: _TenantState, t: float, credit: float):
        if st.capped():
            dt = t - st.tok_t
            if dt > 0.0:
                st.tokens = min(st.tenant.burst,
                                st.tokens + dt * st.tenant.rate_fps * credit)
        st.tok_t = t

    def _admit_one(self, st: _TenantState, m, t: float, live: float):
        """Consume an admission slot + token, advance the WFQ clock, and
        hand the frame to dispatch.  ``m.t_created`` is the *offer* time,
        so front-door queue wait counts against latency and SLO."""
        if st.capped():
            st.tokens -= 1.0
        self._inflight += 1
        self._v = max(self._v, st.vt)
        st.vt = self._v + 1.0 / st.tenant.weight
        self._gate = max(self._gate, t) + 1.0 / (live * self.headroom)
        st.admitted += 1
        wait = t - getattr(m, "t_created", t)
        if wait > 0.0:
            st.wait_s += wait
            if self._tracer is not None and \
                    self._tracer.sampled(getattr(m, "seq", -1)):
                self._tracer.instant(
                    trc.TENANT_ADMIT, t, track=f"tenant:{st.tenant.name}",
                    seq=getattr(m, "seq", -1), wait_s=wait,
                    tenant=st.tenant.name)
        self._admit_cb(m)

    def _schedule_drain(self, t: float):
        if self._drain_pending:
            return
        live, _ = self._capacity_now(t)
        nxt = self._gate if live > 1e-6 else t + self.max_poll_s
        nxt = min(max(nxt, t + 1e-6), t + self.max_poll_s)
        self._drain_pending = True
        self._schedule(nxt, self._drain)

    def _drain(self):
        """Admit queued frames by WFQ order while slots and tokens last;
        re-arm while any backlog remains."""
        self._drain_pending = False
        t = self._clock()
        live, credit = self._capacity_now(t)
        if live <= 1e-6:                # fleet brown-out: hold, re-check
            if self._queued_total:
                self._schedule_drain(t)
            return
        for st in self._states.values():
            self._refill(st, t, credit)
        win = self._window(live)
        while self._gate <= t and self._queued_total and self._inflight < win:
            st = self._next_wfq()
            if st is None:              # backlog exists but no tokens yet
                break
            m = st.queue.popleft()
            self._queued_total -= 1
            self._admit_one(st, m, t, live)
        if self._queued_total:
            self._schedule_drain(t)

    def _next_wfq(self) -> Optional[_TenantState]:
        """Min virtual-finish-time among eligible backlogged tenants
        (deterministic tie-break: class, then name)."""
        best = None
        for st in self._states.values():
            if not st.queue or (st.capped() and st.tokens < 1.0):
                continue
            key = (st.vt, st.tenant.priority, st.tenant.name)
            if best is None or key < best[0]:
                best = (key, st)
        return None if best is None else best[1]

    # -- completion + accounting ----------------------------------------------
    def on_complete(self, name: str, latency_s: float, t: float):
        """Engine callback at frame completion: per-tenant latency and
        SLO accounting, and the ack that frees an admission slot."""
        st = self._states[name]
        st.completed += 1
        st.lat.record(latency_s)
        slo = st.tenant.slo_s
        if slo is not None and latency_s > slo:
            st.slo_miss += 1
        self._inflight = max(0, self._inflight - 1)
        if self._queued_total:          # a slot just freed: ack-clock
            self._schedule_drain(t)

    def on_drop(self, name: str, t: float):
        """Engine callback when an admitted frame is lost in-pipeline:
        the slot must still be returned or the window leaks shut."""
        self._inflight = max(0, self._inflight - 1)
        if self._queued_total:
            self._schedule_drain(t)

    def _trace_shed(self, st: _TenantState, m, t: float, why: str):
        if self._tracer is not None and \
                self._tracer.sampled(getattr(m, "seq", -1)):
            self._tracer.instant(
                trc.TENANT_SHED, t, track=f"tenant:{st.tenant.name}",
                seq=getattr(m, "seq", -1), reason=why,
                tenant=st.tenant.name, priority=st.tenant.priority)

    def check_conservation(self) -> dict:
        """offered == admitted + shed + queued, per tenant.  Returns the
        per-tenant ledger; raises AssertionError on any leak."""
        out = {}
        for name, st in self._states.items():
            ledger = {"offered": st.offered, "admitted": st.admitted,
                      "shed": st.shed, "queued": len(st.queue)}
            assert st.offered == st.admitted + st.shed + len(st.queue), \
                f"front-door conservation leak for {name!r}: {ledger}"
            out[name] = ledger
        return out

    def summary(self) -> dict:
        """JSON-safe snapshot for ``EngineReport.frontdoor`` and the
        ``tenant.*`` metrics namespace."""
        self.check_conservation()
        tenants = {}
        for name, st in self._states.items():
            tn = st.tenant
            goodput = st.completed / st.offered if st.offered else 0.0
            tenants[name] = {
                "class": class_name(tn.priority),
                "priority": tn.priority,
                "weight": tn.weight,
                "rate_fps": tn.rate_fps,
                "slo_s": tn.slo_s,
                "offered": st.offered,
                "admitted": st.admitted,
                "shed": st.shed,
                "shed_overflow": st.shed_overflow,
                "shed_preempted": st.shed_preempted,
                "queued": len(st.queue),
                "queued_peak": st.queued_peak,
                "completed": st.completed,
                "goodput": goodput,
                "avg_wait_s": (st.wait_s / st.admitted
                               if st.admitted else 0.0),
                "slo_miss": st.slo_miss,
                "slo_hit_rate": (1.0 - st.slo_miss / st.completed
                                 if st.completed else 1.0),
                "latency": st.lat.summary(),
            }
        return {
            "engaged": self.engaged,
            "headroom": self.headroom,
            "credit": self.last_credit,
            "offered": sum(s.offered for s in self._states.values()),
            "admitted": sum(s.admitted for s in self._states.values()),
            "shed": sum(s.shed for s in self._states.values()),
            "queued": self._queued_total,
            "tenants": tenants,
        }
