"""Elastic training controller: node failure == cartridge removal.

The CHAMP insight applied to training scale: membership changes are
routine events, not crashes. The controller owns the (data, model) mesh
factorization over however many *healthy* hosts exist; on failure or join
it (1) pauses, (2) re-factorizes the mesh to the largest supported shape,
(3) restores params/optimizer state from the latest committed checkpoint
re-sharded onto the new mesh, (4) replays the data stream from the
restored step (deterministic step-indexed pipeline => no sample loss or
duplication), exactly like VDiSK's pause -> reconfigure -> replay cycle.

Device counts are simulated (CPU container); everything above the mesh
construction is the production logic.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import numpy as np


@dataclass
class ElasticEvent:
    t_step: int
    kind: str           # "fail" | "join" | "remesh" | "restore" | "paused"
    detail: str = ""


def largest_mesh(n_devices: int, model_parallel: int) -> tuple:
    """(data, model) for the largest usable power-of-two data axis.
    ``(0, 0)`` when no devices remain — the all-failed case must degrade
    upstream, not divide by zero here."""
    if n_devices <= 0:
        return (0, 0)
    model = min(model_parallel, n_devices)
    data = n_devices // model
    data = 2 ** int(math.log2(data)) if data else 1
    return (data, model)


class ElasticController:
    def __init__(self, devices: List, *, model_parallel: int = 1,
                 checkpoint_store=None):
        self.all_devices = list(devices)
        self.healthy = set(range(len(devices)))
        self.model_parallel = model_parallel
        self.store = checkpoint_store
        self.events: List[ElasticEvent] = []
        self.mesh = None
        self.remesh(step=0)

    # -- membership -------------------------------------------------------------
    def fail(self, idx: int, step: int):
        self.healthy.discard(idx)
        self.events.append(ElasticEvent(step, "fail", f"device {idx}"))

    def join(self, idx: int, step: int):
        self.healthy.add(idx)
        self.events.append(ElasticEvent(step, "join", f"device {idx}"))

    # -- re-meshing ---------------------------------------------------------------
    @property
    def paused(self) -> bool:
        """True while no healthy devices exist (training cannot proceed;
        the next ``join`` + ``remesh`` resumes)."""
        return self.mesh is None

    def remesh(self, step: int):
        devs = [self.all_devices[i] for i in sorted(self.healthy)]
        if not devs:
            # every device failed: degrade to a paused state instead of
            # crashing on a 0-device mesh (0 // 0, log2(0)); state stays
            # committed in the checkpoint store, so a later join picks up
            # exactly where the last committed step left off
            self.mesh = None
            self.events.append(ElasticEvent(
                step, "paused",
                "0 healthy devices; training paused awaiting join"))
            return None
        data, model = largest_mesh(len(devs), self.model_parallel)
        use = devs[: data * model]
        arr = np.array(use).reshape(data, model)
        self.mesh = jax.sharding.Mesh(arr, ("data", "model"))
        self.events.append(ElasticEvent(
            step, "remesh", f"{data}x{model} over {len(use)} devices"))
        return self.mesh

    # -- recovery ----------------------------------------------------------------
    def recover(self, like, step_hint: Optional[int] = None):
        """Restore latest committed state onto the *current* mesh.

        ``like`` is a pytree of ShapeDtypeStructs/arrays with shardings for
        the new mesh; returns (step, state) re-laid-out via device_put.
        """
        assert self.store is not None
        step, state = self.store.restore(like, step_hint)
        def put(x, l):
            sh = getattr(l, "sharding", None)
            return jax.device_put(x, sh) if sh is not None else x
        state = jax.tree.map(put, state, like)
        self.events.append(ElasticEvent(step, "restore", f"step {step}"))
        return step, state
