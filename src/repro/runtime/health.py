"""Health monitoring + straggler mitigation for serving pipelines.

VDiSK's health daemon, generalized to datacenter scale: every stage (or
data-parallel worker) posts heartbeats; a worker whose in-flight request
exceeds ``straggler_factor x`` the stage's trailing latency percentile gets
its request *backup-dispatched* to a healthy peer (tied-request / hedged
execution — the standard tail-latency mitigation), and a worker that
misses ``dead_after_s`` of heartbeats is declared failed, which triggers
the same path as a cartridge removal (bypass / re-mesh).

The ``StreamEngine``'s hedged shard dispatch is the event-driven face of
the same tied-request machinery: the engine feeds every lane service
start/finish through a ``HealthMonitor`` and reports each hedge through
``record_backup``, so one straggler ledger (``events``,
``backup_dispatches``) covers both the polled datacenter path
(``check``) and the event-driven edge path.
"""
from __future__ import annotations

import math
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


def quantile(xs, q: float) -> float:
    """Nearest-rank quantile of a sequence; +inf when empty (so straggler
    thresholds derived from it never fire without evidence)."""
    if not xs:
        return float("inf")
    s = sorted(xs)
    return s[min(int(math.ceil(q * len(s))) - 1, len(s) - 1)]


@dataclass
class WorkerState:
    last_heartbeat: float = 0.0
    inflight_since: Optional[float] = None
    inflight_id: Optional[int] = None
    done: int = 0
    backup_dispatches: int = 0
    alive: bool = True


class HealthMonitor:
    def __init__(self, *, dead_after_s: float = 3.0,
                 straggler_factor: float = 3.0, window: int = 64):
        self.dead_after_s = dead_after_s
        self.straggler_factor = straggler_factor
        self.workers: Dict[str, WorkerState] = defaultdict(WorkerState)
        self.latencies: deque = deque(maxlen=window)
        self.events: List[tuple] = []

    def heartbeat(self, worker: str, t: float):
        w = self.workers[worker]
        w.last_heartbeat = t
        if not w.alive:
            w.alive = True
            self.events.append((t, "rejoin", worker))

    def start_request(self, worker: str, req_id: int, t: float):
        w = self.workers[worker]
        w.inflight_since, w.inflight_id = t, req_id
        w.last_heartbeat = t

    def finish_request(self, worker: str, t: float):
        w = self.workers[worker]
        if w.inflight_since is not None:
            self.latencies.append(t - w.inflight_since)
        w.inflight_since = w.inflight_id = None
        w.done += 1
        w.last_heartbeat = t

    def record_backup(self, worker: str, t: float,
                      req_id: Optional[int] = None):
        """Note that ``worker``'s in-flight request was backup-dispatched
        (tied-request hedge) to a peer.  Shared ledger entry for both the
        polled ``check`` path and the engine's event-driven hedge path."""
        self.workers[worker].backup_dispatches += 1
        self.events.append((t, "straggler", worker))

    def _p90(self) -> float:
        return quantile(self.latencies, 0.9)

    def check(self, t: float):
        """Returns (dead_workers, straggler (worker, req_id) pairs)."""
        dead, stragglers = [], []
        thresh = self.straggler_factor * self._p90()
        for name, w in self.workers.items():
            if not w.alive:
                continue
            if t - w.last_heartbeat > self.dead_after_s:
                w.alive = False
                dead.append(name)
                self.events.append((t, "dead", name))
                continue
            if w.inflight_since is not None and \
                    t - w.inflight_since > thresh:
                stragglers.append((name, w.inflight_id))
                self.record_backup(name, t, w.inflight_id)
        return dead, stragglers
