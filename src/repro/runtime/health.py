"""Health monitoring + straggler mitigation for serving pipelines.

VDiSK's health daemon, generalized to datacenter scale: every stage (or
data-parallel worker) posts heartbeats; a worker whose in-flight request
exceeds ``straggler_factor x`` the stage's trailing latency percentile gets
its request *backup-dispatched* to a healthy peer (tied-request / hedged
execution — the standard tail-latency mitigation), and a worker that
misses ``dead_after_s`` of heartbeats is declared failed, which triggers
the same path as a cartridge removal (bypass / re-mesh).
"""
from __future__ import annotations

import math
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional


@dataclass
class WorkerState:
    last_heartbeat: float = 0.0
    inflight_since: Optional[float] = None
    inflight_id: Optional[int] = None
    done: int = 0
    backup_dispatches: int = 0
    alive: bool = True


class HealthMonitor:
    def __init__(self, *, dead_after_s: float = 3.0,
                 straggler_factor: float = 3.0, window: int = 64):
        self.dead_after_s = dead_after_s
        self.straggler_factor = straggler_factor
        self.workers: Dict[str, WorkerState] = defaultdict(WorkerState)
        self.latencies: deque = deque(maxlen=window)
        self.events: List[tuple] = []

    def heartbeat(self, worker: str, t: float):
        w = self.workers[worker]
        w.last_heartbeat = t
        if not w.alive:
            w.alive = True
            self.events.append((t, "rejoin", worker))

    def start_request(self, worker: str, req_id: int, t: float):
        w = self.workers[worker]
        w.inflight_since, w.inflight_id = t, req_id
        w.last_heartbeat = t

    def finish_request(self, worker: str, t: float):
        w = self.workers[worker]
        if w.inflight_since is not None:
            self.latencies.append(t - w.inflight_since)
        w.inflight_since = w.inflight_id = None
        w.done += 1
        w.last_heartbeat = t

    def _p90(self) -> float:
        if not self.latencies:
            return float("inf")
        xs = sorted(self.latencies)
        return xs[min(int(math.ceil(0.9 * len(xs))) - 1, len(xs) - 1)]

    def check(self, t: float):
        """Returns (dead_workers, straggler (worker, req_id) pairs)."""
        dead, stragglers = [], []
        thresh = self.straggler_factor * self._p90()
        for name, w in self.workers.items():
            if not w.alive:
                continue
            if t - w.last_heartbeat > self.dead_after_s:
                w.alive = False
                dead.append(name)
                self.events.append((t, "dead", name))
                continue
            if w.inflight_since is not None and \
                    t - w.inflight_since > thresh:
                stragglers.append((name, w.inflight_id))
                w.backup_dispatches += 1
                self.events.append((t, "straggler", name))
        return dead, stragglers
