"""Health monitoring + straggler mitigation for serving pipelines.

VDiSK's health daemon, generalized to datacenter scale: every stage (or
data-parallel worker) posts heartbeats; a worker whose in-flight request
exceeds ``straggler_factor x`` the stage's trailing latency percentile gets
its request *backup-dispatched* to a healthy peer (tied-request / hedged
execution — the standard tail-latency mitigation), and a worker that
misses ``dead_after_s`` of heartbeats is declared failed, which triggers
the same path as a cartridge removal (bypass / re-mesh).

The ``StreamEngine``'s hedged shard dispatch is the event-driven face of
the same tied-request machinery: the engine feeds every lane service
start/finish through a ``HealthMonitor`` and reports each hedge through
``record_backup``, so one straggler ledger (``events``,
``backup_dispatches``) covers both the polled datacenter path
(``check``) and the event-driven edge path.
"""
from __future__ import annotations

import math
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from .faults import QuarantinePolicy


def quantile(xs, q: float) -> float:
    """Nearest-rank quantile of a sequence; +inf when empty (so straggler
    thresholds derived from it never fire without evidence)."""
    if not xs:
        return float("inf")
    s = sorted(xs)
    return s[min(int(math.ceil(q * len(s))) - 1, len(s) - 1)]


@dataclass(slots=True)
class WorkerState:
    last_heartbeat: float = 0.0
    inflight_since: Optional[float] = None
    inflight_id: Optional[int] = None
    done: int = 0
    backup_dispatches: int = 0
    alive: bool = True


class HealthMonitor:
    def __init__(self, *, dead_after_s: float = 3.0,
                 straggler_factor: float = 3.0, window: int = 64):
        self.dead_after_s = dead_after_s
        self.straggler_factor = straggler_factor
        self.workers: Dict[str, WorkerState] = defaultdict(WorkerState)
        self.latencies: deque = deque(maxlen=window)
        self.events: List[tuple] = []

    def heartbeat(self, worker: str, t: float):
        w = self.workers[worker]
        w.last_heartbeat = t
        if not w.alive:
            w.alive = True
            self.events.append((t, "rejoin", worker))

    def start_request(self, worker: str, req_id: int, t: float):
        w = self.workers[worker]
        w.inflight_since, w.inflight_id = t, req_id
        w.last_heartbeat = t

    def finish_request(self, worker: str, t: float):
        w = self.workers[worker]
        if w.inflight_since is not None:
            self.latencies.append(t - w.inflight_since)
        w.inflight_since = w.inflight_id = None
        w.done += 1
        w.last_heartbeat = t

    def abort_request(self, worker: str, t: float):
        """The in-flight request died with its worker (crash, or a hang
        promoted by the watchdog): clear it *without* recording a latency
        sample — the request never completed, and a fault must not teach
        the straggler threshold that slow is normal."""
        w = self.workers[worker]
        w.inflight_since = w.inflight_id = None
        self.events.append((t, "aborted", worker))

    def record_backup(self, worker: str, t: float,
                      req_id: Optional[int] = None):
        """Note that ``worker``'s in-flight request was backup-dispatched
        (tied-request hedge) to a peer.  Shared ledger entry for both the
        polled ``check`` path and the engine's event-driven hedge path."""
        self.workers[worker].backup_dispatches += 1
        self.events.append((t, "straggler", worker))

    def _p90(self) -> float:
        return quantile(self.latencies, 0.9)

    def check(self, t: float):
        """Returns (dead_workers, straggler (worker, req_id) pairs)."""
        dead, stragglers = [], []
        thresh = self.straggler_factor * self._p90()
        for name, w in self.workers.items():
            if not w.alive:
                continue
            if t - w.last_heartbeat > self.dead_after_s:
                w.alive = False
                dead.append(name)
                self.events.append((t, "dead", name))
                continue
            if w.inflight_since is not None and \
                    t - w.inflight_since > thresh:
                stragglers.append((name, w.inflight_id))
                self.record_backup(name, t, w.inflight_id)
        return dead, stragglers


@dataclass(slots=True)
class _Lease:
    until: float = 0.0            # quarantined while now < until
    lease_s: float = 0.0          # the lease this bench was granted
    probation_until: float = 0.0  # penalized (and flap-sensitive) window
    faults: int = 0
    flaps: int = 0
    reinstatements: int = 0


class QuarantineLedger:
    """Lease-based lane quarantine with probationary reinstatement.

    The engine benches a faulted lane here; while quarantined the lane is
    excluded from every pick set (shard, hedge backup, broadcast, route
    fallback).  When the lease expires the lane re-enters *on probation*:
    its completion estimate is inflated by ``policy.probation_penalty``
    so it earns traffic back gradually instead of re-entering the EWMA
    loop at full weight.

    Hysteresis: a fault during probation — the signature of a flapper —
    doubles the lease (``policy.flap_factor``, capped at
    ``policy.lease_cap_s``).  A lane that fails at exactly the probation
    period therefore sits out 1×, 2×, 4×, … leases rather than
    oscillating in and out of the pick set every cycle; the boundary
    itself (``t == probation_until``) counts as a flap so the oscillation
    period has no resonant fixed point.
    """

    def __init__(self, policy: Optional[QuarantinePolicy] = None):
        self.policy = policy or QuarantinePolicy()
        self._st: Dict[str, _Lease] = {}
        # optional FlightRecorder: quarantine/reinstate emit instants —
        # the ledger alone sees flap escalation, so it owns the detail
        self.tracer = None

    def quarantine(self, name: str, t: float,
                   min_lease_s: float = 0.0) -> float:
        """Bench ``name`` at time ``t``; returns the lease expiry."""
        p = self.policy
        st = self._st.setdefault(name, _Lease())
        flapped = st.faults > 0 and t <= st.probation_until
        if flapped:
            # Faulted while quarantined or on probation: flap — escalate.
            st.flaps += 1
            st.lease_s = min(max(st.lease_s, p.lease_s) * p.flap_factor,
                             p.lease_cap_s)
        else:
            st.lease_s = p.lease_s
        st.lease_s = max(st.lease_s, min_lease_s)
        st.faults += 1
        st.until = t + st.lease_s
        st.probation_until = st.until + p.probation_s
        if self.tracer is not None:
            self.tracer.instant("quarantine", t, track=name,
                                lease_s=st.lease_s, until=st.until,
                                flapped=flapped, faults=st.faults)
        return st.until

    def quarantined(self, name: str, t: float) -> bool:
        st = self._st.get(name)
        return st is not None and t < st.until

    def until(self, name: str) -> float:
        st = self._st.get(name)
        return st.until if st is not None else 0.0

    def penalty(self, name: str, t: float) -> float:
        """Completion-estimate multiplier for the pick loop: the
        probation penalty while on probation, 1.0 once clean."""
        st = self._st.get(name)
        if st is None or t >= st.probation_until or t < st.until:
            return 1.0
        return self.policy.probation_penalty

    def reinstate(self, name: str, t: float):
        st = self._st.get(name)
        if st is not None:
            st.reinstatements += 1
            if self.tracer is not None:
                self.tracer.instant("reinstate", t, track=name,
                                    probation_until=st.probation_until,
                                    penalty=self.policy.probation_penalty)

    def summary(self) -> dict:
        return {name: {"faults": st.faults, "flaps": st.flaps,
                       "reinstatements": st.reinstatements,
                       "lease_s": st.lease_s}
                for name, st in sorted(self._st.items())}
