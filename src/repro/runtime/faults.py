"""Deterministic fault injection for the chaos fabric.

CHAMP's pitch is field operation — sticks die, hubs brown out, USB links
flake mid-mission — so the engine needs *unplanned* membership change as
a first-class event, not just hot-swap (planned) and hedging (slowness).
This module is the pure-data half of that story: a ``FaultPlan`` schedules
faults at virtual timestamps, a ``RetryPolicy`` shapes the backoff of
every recovery path, and a ``QuarantinePolicy`` tunes the lease/probation
state machine that keeps flapping lanes out of the EWMA pick loop.  The
mechanism that *acts* on these lives in ``engine.py`` / ``health.py`` /
``fabric.py``.

Everything here is replay-stable: all randomness comes from crc32 hashes
of (seed, kind, index) tuples, never from ``random`` or wall-clock, so
the same plan against the same scenario produces the same event trace on
every run and every host — the property the chaos bench's bit-identity
checks and the zero-loss CI gate both lean on.

Fault kinds
-----------
``LANE_CRASH``     the device vanishes mid-cycle; in-flight and queued
                   frames are re-dispatched, the lane is quarantined.
``LANE_HANG``      the service cycle never completes; the watchdog
                   (hedge-deadline histogram × margin) promotes the hang
                   into a failure.
``HUB_POWER_LOSS`` every lane on the hub crashes at once; the governor's
                   population sync stops their energy draw.
``LINK_DOWN``      an inter-hub link dies for ``duration`` seconds; the
                   router prices it at +inf and dispatch falls back to
                   alternate hubs (or holds frames until restore).
Transfer corruption is rate-based rather than scheduled: each bus
handoff draws against ``corrupt_p`` keyed on (seed, seq, attempt), and a
frame checksum at the receiver turns a hit into a detect + re-send.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Iterable, List, Optional, Sequence, Tuple, Union

LANE_CRASH = "lane_crash"
LANE_HANG = "lane_hang"
HUB_POWER_LOSS = "hub_power_loss"
LINK_DOWN = "link_down"

FAULT_KINDS = (LANE_CRASH, LANE_HANG, HUB_POWER_LOSS, LINK_DOWN)


def _u01(*parts) -> float:
    """Deterministic uniform in [0, 1) from a crc32 hash of the parts.

    Replay- and process-stable (no PYTHONHASHSEED dependence), matching
    the engine's service-jitter discipline.
    """
    key = ":".join(str(p) for p in parts).encode()
    return (zlib.crc32(key) & 0xFFFFFFFF) / 4294967296.0


def frame_checksum(m) -> int:
    """Checksum stamped on each frame at bus handoff and verified at the
    receiver.  Covers the identity fields a corrupted transfer would
    scramble; the stamp itself lives in ``m.meta['_csum']`` and is *not*
    part of the hashed payload, so verification is self-consistent."""
    return zlib.crc32(f"{m.seq}:{m.kind}:{m.meta.get('bytes', 0)}".encode())


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``target`` is a lane cartridge name for
    lane faults, a hub id for ``HUB_POWER_LOSS``, and an ``(a, b)`` hub
    pair for ``LINK_DOWN``.  ``duration`` is the outage window for link
    faults and the minimum quarantine lease for crash/power faults
    (0 → policy default)."""

    t: float
    kind: str
    target: Union[str, int, Tuple[int, int]]
    duration: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of {FAULT_KINDS}")
        if self.t < 0 or self.duration < 0:
            raise ValueError("fault time/duration must be >= 0")

    def describe(self) -> dict:
        """Trace-arg form: plain scalars only (the ``(a, b)`` link
        target stringifies so exports stay JSON-stable)."""
        return {"fault": self.kind, "target": str(self.target),
                "duration_s": self.duration}


@dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff with deterministic jitter and a retry budget.

    Every recovery path (crashed-lane re-dispatch, corrupt-frame re-send,
    blocked-route re-probe) waits ``backoff(attempt)`` before trying
    again.  Jitter decorrelates retries that failed together without
    breaking replay: the draw is keyed on the caller-supplied key (frame
    seq), not on a PRNG stream.  The budget never *drops* a frame — zero
    loss is the contract — it marks the frame's alert threshold: once a
    frame burns more than ``budget`` retries the engine raises an alert
    so operators see pathological cells instead of silent crawling.
    """

    base_s: float = 0.005
    factor: float = 2.0
    max_s: float = 0.25
    jitter: float = 0.25
    budget: int = 6

    def backoff(self, attempt: int, key: str = "") -> float:
        d = min(self.base_s * self.factor ** max(attempt, 0), self.max_s)
        if self.jitter > 0:
            d *= 1.0 + self.jitter * (2.0 * _u01("retry", key, attempt) - 1.0)
        return d


@dataclass(frozen=True)
class QuarantinePolicy:
    """Lease-based quarantine with probationary reinstatement.

    A failed lane is benched for ``lease_s``; after the lease it re-enters
    the pick set *on probation* for ``probation_s`` with its completion
    estimate inflated by ``probation_penalty`` (so a returning lane must
    earn traffic back rather than re-entering the EWMA loop at full
    weight).  A fault during probation is a *flap*: the next lease is the
    previous one × ``flap_factor`` (capped at ``lease_cap_s``) — the
    hysteresis that stops a lane flapping at exactly the probation period
    from oscillating in and out of the pick set every cycle.
    """

    lease_s: float = 0.5
    lease_cap_s: float = 30.0
    flap_factor: float = 2.0
    probation_s: float = 0.5
    probation_penalty: float = 4.0


class FaultPlan:
    """A seeded, deterministic schedule of faults plus a transfer
    corruption rate.  Immutable once built; safe to share across runs
    (replaying the same plan gives the same fault trace)."""

    def __init__(self, events: Iterable[FaultEvent] = (),
                 corrupt_p: float = 0.0, seed: int = 0):
        if not 0.0 <= corrupt_p < 1.0:
            raise ValueError("corrupt_p must be in [0, 1)")
        self.events: Tuple[FaultEvent, ...] = tuple(
            sorted(events, key=lambda e: (e.t, e.kind, str(e.target))))
        self.corrupt_p = float(corrupt_p)
        self.seed = int(seed)

    @property
    def empty(self) -> bool:
        return not self.events and self.corrupt_p <= 0.0

    def corrupt_draw(self, seq: int, attempt: int) -> bool:
        """Does transmission ``attempt`` of frame ``seq`` corrupt?  Keyed
        per-attempt so a re-send of a corrupted frame redraws (and a
        retried frame isn't doomed to corrupt forever)."""
        if self.corrupt_p <= 0.0:
            return False
        return _u01(self.seed, "corrupt", seq, attempt) < self.corrupt_p

    def describe(self) -> dict:
        kinds: dict = {}
        for ev in self.events:
            kinds[ev.kind] = kinds.get(ev.kind, 0) + 1
        return {"seed": self.seed, "n_events": len(self.events),
                "by_kind": kinds, "corrupt_p": self.corrupt_p}

    @classmethod
    def storm(cls, seed: int, horizon_s: float, *,
              lanes: Sequence[str] = (),
              hubs: Sequence[int] = (),
              links: Sequence[Tuple[int, int]] = (),
              crash_rate: float = 0.0,
              hang_rate: float = 0.0,
              hub_loss_rate: float = 0.0,
              link_down_rate: float = 0.0,
              link_down_s: float = 0.15,
              corrupt_p: float = 0.0,
              t0: float = 0.05) -> "FaultPlan":
        """Generate a seeded fault storm: for each kind, ``rate`` is
        events per simulated second across the whole target set; event
        times and victims are hashed from (seed, kind, index), so the
        same arguments always yield the same storm.

        ``t0`` offsets the window so faults never land before the first
        frame is in flight (a crash at t=0 against an empty engine tests
        nothing).
        """
        span = max(horizon_s - t0, 0.0)
        events: List[FaultEvent] = []

        def _emit(kind: str, rate: float, targets: Sequence, duration_of):
            if rate <= 0 or span <= 0 or not targets:
                return
            n = int(round(rate * span))
            for i in range(n):
                t = t0 + span * _u01(seed, kind, "t", i)
                tgt = targets[int(_u01(seed, kind, "who", i) * len(targets))
                              % len(targets)]
                events.append(FaultEvent(t, kind, tgt, duration_of(i)))

        _emit(LANE_CRASH, crash_rate, list(lanes), lambda i: 0.0)
        _emit(LANE_HANG, hang_rate, list(lanes), lambda i: 0.0)
        _emit(HUB_POWER_LOSS, hub_loss_rate, list(hubs), lambda i: 0.0)
        _emit(LINK_DOWN, link_down_rate, list(links),
              lambda i: link_down_s * (0.5 + _u01(seed, LINK_DOWN, "dur", i)))
        return cls(events, corrupt_p=corrupt_p, seed=seed)
