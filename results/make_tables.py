"""Render the §Roofline tables for EXPERIMENTS.md from sweep JSONLs."""
import json
import sys


def load(path):
    by = {}
    try:
        for line in open(path):
            r = json.loads(line)
            by[(r["arch"], r["shape"])] = r
    except FileNotFoundError:
        pass
    return by


def table(by, title):
    out = [f"### {title}", "",
           "| arch | shape | rules | compute_s | memory_s | collective_s |"
           " dominant | useful | mem/dev GiB (raw / TPU-adj) | fits |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for (a, s), r in sorted(by.items()):
        if r["status"] == "skip":
            out.append(f"| {a} | {s} | — | — | — | — | skip | — | — | "
                       f"full-attn 500k skip |")
            continue
        if r["status"] != "ok":
            out.append(f"| {a} | {s} | — | ERROR | | | | | | |")
            continue
        rl, m = r["roofline"], r["memory"]
        out.append(
            f"| {a} | {s} | {r['rules']} | {rl['compute_s']:.3e} | "
            f"{rl['memory_s']:.3e} | {rl['collective_s']:.3e} | "
            f"{rl['dominant']} | {rl['useful_ratio']:.2f} | "
            f"{m['per_device_total']/2**30:.2f} / "
            f"{m['per_device_tpu_adjusted']/2**30:.2f} | "
            f"{'yes' if m['fits_hbm'] else 'NO'} |")
    return "\n".join(out)


if __name__ == "__main__":
    single = load("results/dryrun_single.jsonl")
    multi = load("results/dryrun_multi.jsonl")
    print(table(single, "16x16 single pod (roofline baseline)"))
    print()
    if multi:
        ok = sum(1 for r in multi.values() if r["status"] == "ok")
        sk = sum(1 for r in multi.values() if r["status"] == "skip")
        print(f"### 2x16x16 multi-pod: {ok} ok / {sk} skip / "
              f"{len(multi)-ok-sk} failed (compile-proof; roofline table is "
              f"single-pod per the brief)")
